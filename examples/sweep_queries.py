"""Resumable sweeps + the trigger-threshold query service, end to end.

The deployment question the paper answers is "which λ?": what trigger
threshold hits my communication budget and what value-function error
does it cost.  This example

  1. runs a λ frontier grid through the *resumable* runtime (kill it at
     any point and re-run this script — it picks up at the last finished
     chunk, bitwise identical),
  2. lands the summaries in an append-only SweepStore,
  3. extends the grid with extra λ points, computing only the new cells,
  4. answers budget queries from the store with zero device work
     (the same answers `python -m repro.experiments.serve_sweeps STORE`
     serves over HTTP),
  5. moves to the serving tier: a `StoreRegistry` precomputes the
     entry's `QueryTable` once, then a budget *vector* is one pure
     numpy lookup — what `GET /query/best_lambda?budget=0.05,0.2,…`
     and `POST /query/batch` answer per round trip under load
     (benchmarks/serve_load.py).

  PYTHONPATH=src python examples/sweep_queries.py
"""

import dataclasses
import os

import jax.numpy as jnp
import numpy as np

from repro.core.algorithm1 import ParamSampler
from repro.envs import GridWorld
from repro.experiments import SweepSpec
from repro.experiments import query
from repro.experiments.runtime import run_sweep_extend, run_sweep_resumable
from repro.experiments.store import SweepStore, spec_hash

ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                    "stores", "quickstart")

# 1. the experiment: a λ frontier on the windy grid (paper §V / Fig. 2)
gw = GridWorld()
prob = gw.vfa_problem(np.zeros(gw.num_states))
w0 = jnp.zeros(gw.num_states)
spec = SweepSpec(
    modes=("theoretical", "practical"),
    lambdas=tuple(np.logspace(-4, -1, 6)),
    seeds=(0, 1, 2), rhos=(prob.min_rho(0.5) * 1.0001,), eps=0.5,
    num_iterations=200, num_agents=2,
    trace="summary",          # O(1)-memory streaming summaries
    chunk_size=6,             # checkpoint granularity: 6 runs per segment
)
sampler = ParamSampler(fn=gw.sampler_fn(10), params=gw.agent_params(w0, 2))

store = SweepStore(os.path.join(ROOT, "store"))
res = run_sweep_resumable(
    spec, sampler, w0, problem=prob,
    store_dir=os.path.join(ROOT, "chunks"),       # kill + re-run => resume
    summary_store=store,
    on_chunk=lambda i, n, restored: print(
        f"  chunk {i + 1}/{n} {'restored' if restored else 'computed'}"))
print(f"sweep {spec_hash(spec)[:12]}… in store "
      f"({int(np.prod(spec.grid_shape))} runs)")

# 2. extend the frontier: only the two new λ columns are computed
wider = dataclasses.replace(spec, lambdas=spec.lambdas + (3e-1, 1.0))
run_sweep_extend(store, wider, sampler, w0, problem=prob)
print(f"extended to {len(wider.lambdas)} λ points "
      f"(store entries: {len(store.hashes())})")

# 3. deployment-time questions, answered from disk — no device, no jax
#    needed on the serving host (see repro.experiments.serve_sweeps)
entry = store.get(wider)
curve = query.tradeoff_curve(entry, mode="theoretical")
for budget in (0.8, 0.5, 0.2):
    best = query.best_lambda(curve, budget)
    tag = "" if best["feasible"] else "  (budget unmet — closest)"
    print(f"comm budget {budget:4.0%} -> λ = {best['lam']:.3e}  "
          f"comm = {best['comm_rate']:5.1%}  J = {best['J']:.3e}{tag}")
print("pareto front (comm, J):",
      [(round(r["comm_rate"], 3), round(r["J"], 4))
       for r in query.pareto_front(curve)])

# 4. the serving tier: register the store once, query tables forever.
#    StoreRegistry federates any number of roots; table() precomputes
#    every (mode, rho) curve at registration so each answer below is a
#    pure lookup (the HTTP server routes every request through this).
from repro.experiments import StoreRegistry  # noqa: E402 — jax-free half

reg = StoreRegistry([os.path.join(ROOT, "store")])
table = reg.table(spec_hash(wider))
batch = table.best_lambda_batch([0.05, 0.2, 0.5, 0.8])   # one numpy pass
print("budget vector ->",
      [(b["comm_budget"], f"{b['lam']:.2e}") for b in batch])
print("registry stats:", reg.stats)         # 1 entry load, then all hits

store_path = os.path.normpath(os.path.join(ROOT, "store"))
print(f"\nserve it:  PYTHONPATH=src python -m repro.experiments.serve_sweeps "
      f"{store_path}\nthen:      GET /query/best_lambda?budget=0.05,0.2,0.5 "
      f"| POST /query/batch")
