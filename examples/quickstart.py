"""Quickstart: the paper in ~60 lines.

Two agents jointly fit the value function of a random-walk policy on the
5x5 windy grid (paper §V, Fig. 2), communicating only when their local
data is informative enough (eq. 9 with the practical estimate eq. 15).

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GatedSGDConfig, TriggerConfig, run_gated_sgd
from repro.envs import GridWorld

# 1. the MDP and the exact quantities we need for evaluation
gw = GridWorld()                                  # 5x5, windy top row, goal G
v_current = np.zeros(gw.num_states)               # initial value guess
problem = gw.vfa_problem(v_current)               # population problem (3)
print(f"J(w0) = {problem.objective(jnp.zeros(gw.num_states)):.4f}, "
      f"J(w*) = {problem.objective(problem.optimum()):.2e}")

# 2. stability constants from the paper's Assumptions 2-3
eps = 0.5
rho = problem.min_rho(eps) * 1.0001
print(f"eps = {eps} (max stable {problem.max_stable_stepsize():.2f}), rho = {rho:.4f}")

# 3. run Algorithm 1's inner loop at three communication prices
sampler = gw.make_sampler(jnp.asarray(v_current), num_samples=10)
for lam in (1e-4, 1e-2, 1e-1):
    cfg = GatedSGDConfig(
        trigger=TriggerConfig(lam=lam, rho=rho, num_iterations=250),
        eps=eps, num_agents=2, mode="practical",   # eq. 15, model-free
    )
    trace = run_gated_sgd(jax.random.key(0), jnp.zeros(gw.num_states),
                          sampler, cfg, problem=problem)
    j_final = float(problem.objective(trace.weights[-1]))
    print(f"lambda={lam:7.0e}  comm rate={float(trace.comm_rate):5.1%}  "
          f"J(w_N)={j_final:.2e}")

print("\nHigher lambda => less communication, gracefully worse J — "
      "the tradeoff Theorem 1 guarantees.")
